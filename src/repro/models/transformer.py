"""Decoder-only LM supporting all assigned families.

One parameter pytree, ``lax.scan`` over stacked layer weights (keeps HLO and
compile time depth-independent), three entry points:

  * ``forward``      -- train / full-sequence logits (tokens or embeddings in)
  * ``prefill``      -- forward + build decode cache
  * ``decode_step``  -- one token with KV cache / SSM state

Hybrid (Zamba2) runs an outer scan over cycles: one *shared* attention+MLP
block (single weight set) followed by ``shared_attn_every`` Mamba2 layers per
cycle.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.act_sharding import shard

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: dict = {}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        p["attn"] = L.init_attention(cfg, ks[0], cfg.d_model, dtype)
        if cfg.family == "moe":
            p["ffn"] = MOE.init_moe(cfg, ks[1], dtype)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.parametric_norm:
            p["ln1"] = jnp.ones((cfg.d_model,), dtype)
            p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.family == "ssm":
        p["mixer"] = SSM.init_mamba1(cfg, ks[0], dtype)
        if cfg.parametric_norm:
            p["ln"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.family == "hybrid":
        p["mixer"] = SSM.init_mamba2(cfg, ks[0], dtype)
        if cfg.parametric_norm:
            p["ln"] = jnp.ones((cfg.d_model,), dtype)
    else:
        raise ValueError(cfg.family)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
        * cfg.d_model**-0.5
    }
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_init_layer(cfg, keys[1 + i], dtype) for i in range(cfg.num_layers)],
    )
    if cfg.family == "hybrid":
        n_cyc = cfg.num_layers // cfg.shared_attn_every
        stacked = jax.tree.map(
            lambda x: x.reshape(n_cyc, cfg.shared_attn_every, *x.shape[1:]), stacked
        )
        kk = jax.random.split(keys[-1], 2)
        params["shared"] = {
            "attn": L.init_attention(cfg, kk[0], cfg.d_model, dtype),
            "ffn": L.init_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
        }
        if cfg.parametric_norm:
            params["shared"]["ln1"] = jnp.ones((cfg.d_model,), dtype)
            params["shared"]["ln2"] = jnp.ones((cfg.d_model,), dtype)
    params["layers"] = stacked
    if cfg.parametric_norm:
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size), dtype)
            * cfg.d_model**-0.5
        )
    return params


# ---------------------------------------------------------------------------
# Layer bodies (full sequence)
# ---------------------------------------------------------------------------


def _dense_layer(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln1"))
    x = x + L.attention_block(cfg, p["attn"], h, impl=impl)
    h = L.norm(cfg, x, p.get("ln2"))
    if cfg.family == "moe":
        y, aux, dropped = MOE.moe_block(cfg, p["ffn"], h)
        return x + y, aux, dropped
    return x + L.mlp_block(p["ffn"], h), jnp.float32(0), jnp.float32(0)


def _ssm_layer(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln"))
    if cfg.family == "hybrid":
        return x + SSM.mamba2_block(cfg, p["mixer"], h)
    return x + SSM.mamba1_block(cfg, p["mixer"], h, impl=impl)


def _shared_block(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln1"))
    x = x + L.attention_block(cfg, p["attn"], h, impl=impl)
    h = L.norm(cfg, x, p.get("ln2"))
    return x + L.mlp_block(p["ffn"], h)


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full": save nothing


# ---------------------------------------------------------------------------
# Forward (train / logits over full sequence)
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array, dtype):
    return params["embed"].astype(dtype)[tokens]


def unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    *,
    impl: str = "xla",
    remat_policy: str = "none",
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, dict]:
    """inputs: int tokens [B, S] or (embed_inputs archs) embeddings [B, S, d].
    Returns (logits [B, S, V], metrics)."""
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed_tokens(cfg, params, inputs, compute_dtype)
    else:
        assert cfg.embed_inputs, f"{cfg.name} does not take embedding inputs"
        x = inputs.astype(compute_dtype)
    x = shard(x, "btd")

    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    if cfg.family == "hybrid":
        shared = cast(params["shared"])

        def cycle(xc, cyc_params):
            xc = _shared_block(cfg, shared, xc, impl)

            def inner(xi, lp):
                return shard(_ssm_layer(cfg, lp, xi, impl), "btd"), None

            xc, _ = jax.lax.scan(inner, xc, cyc_params)
            return xc, None

        body = _maybe_remat(cycle, remat_policy)
        x, _ = jax.lax.scan(body, x, cast(params["layers"]))
        aux = dropped = jnp.float32(0)
    elif cfg.family == "ssm":

        def body(xc, lp):
            return shard(_ssm_layer(cfg, lp, xc, impl), "btd"), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat_policy), x, cast(params["layers"]))
        aux = dropped = jnp.float32(0)
    else:

        def body(xc, lp):
            xc, a, dr = _dense_layer(cfg, lp, xc, impl)
            return shard(xc, "btd"), (a, dr)

        x, (auxs, drops) = jax.lax.scan(
            _maybe_remat(body, remat_policy), x, cast(params["layers"])
        )
        aux, dropped = auxs.mean(), drops.mean()

    x = L.norm(cfg, x, params.get("final_norm"))
    logits = shard(unembed(cfg, params, x), "btv")
    return logits, {"moe_aux": aux, "moe_dropped": dropped}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    labels: jax.Array,
    *,
    impl: str = "xla",
    remat_policy: str = "none",
    compute_dtype=jnp.bfloat16,
    moe_aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    logits, metrics = forward(
        cfg, params, inputs, impl=impl, remat_policy=remat_policy,
        compute_dtype=compute_dtype,
    )
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    loss = ce + moe_aux_weight * metrics["moe_aux"]
    metrics = dict(metrics, ce=ce, loss=loss)
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> Params:
    l, hd = cfg.num_layers, cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        kv = lambda: jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, hd), dtype)
        layer_state = {"k": kv(), "v": kv()}
    elif cfg.family == "ssm":
        st = SSM.mamba1_init_state(cfg, batch, dtype)
        layer_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (l, *a.shape)), st
        )
    elif cfg.family == "hybrid":
        n_cyc = l // cfg.shared_attn_every
        st = SSM.mamba2_init_state(cfg, batch, dtype)
        layer_state = {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (n_cyc, cfg.shared_attn_every, *a.shape)
                ),
                st,
            ),
            "shared_k": jnp.zeros(
                (n_cyc, batch, max_seq, cfg.num_kv_heads, hd), dtype
            ),
            "shared_v": jnp.zeros(
                (n_cyc, batch, max_seq, cfg.num_kv_heads, hd), dtype
            ),
        }
    else:
        raise ValueError(cfg.family)
    return {"index": jnp.int32(0), "layers": layer_state}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    cache: Params,
    *,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    """tokens: [B] int32 (last generated).  Returns (logits [B, V], cache).

    ``cache["index"]`` may be scalar (uniform batch) or [B] per-slot
    positions (continuous batching)."""
    x = params["embed"].astype(compute_dtype)[tokens][:, None, :]  # [B, 1, d]
    idx = cache["index"]
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    if cfg.family in ("dense", "moe", "audio", "vlm"):

        def body(xc, per_layer):
            lp, k_c, v_c = per_layer
            h = L.norm(cfg, xc, lp.get("ln1"))
            y, (k_c, v_c) = L.attention_decode(cfg, lp["attn"], h, (k_c, v_c), idx)
            xc = xc + y
            h = L.norm(cfg, xc, lp.get("ln2"))
            if cfg.family == "moe":
                y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
            else:
                y2 = L.mlp_block(lp["ffn"], h)
            return xc + y2, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (cast(params["layers"]), cache["layers"]["k"], cache["layers"]["v"])
        )
        new_layers = {"k": k_new, "v": v_new}
    elif cfg.family == "ssm":

        def body(xc, per_layer):
            lp, st = per_layer
            h = L.norm(cfg, xc, lp.get("ln"))
            y, st = SSM.mamba1_step(cfg, lp["mixer"], h[:, 0], st)
            return xc + y[:, None], st

        x, new_layers = jax.lax.scan(
            body, x, (cast(params["layers"]), cache["layers"])
        )
    else:  # hybrid
        shared = cast(params["shared"])

        def cycle(xc, per_cycle):
            cyc_params, mamba_st, k_c, v_c = per_cycle
            h = L.norm(cfg, xc, shared.get("ln1"))
            y, (k_c, v_c) = L.attention_decode(cfg, shared["attn"], h, (k_c, v_c), idx)
            xc = xc + y
            h = L.norm(cfg, xc, shared.get("ln2"))
            xc = xc + L.mlp_block(shared["ffn"], h)

            def inner(xi, per_layer):
                lp, st = per_layer
                hh = L.norm(cfg, xi, lp.get("ln"))
                yy, st = SSM.mamba2_step(cfg, lp["mixer"], hh[:, 0], st)
                return xi + yy[:, None], st

            xc, mamba_st = jax.lax.scan(inner, xc, (cyc_params, mamba_st))
            return xc, (mamba_st, k_c, v_c)

        x, (m_new, k_new, v_new) = jax.lax.scan(
            cycle,
            x,
            (
                cast(params["layers"]),
                cache["layers"]["mamba"],
                cache["layers"]["shared_k"],
                cache["layers"]["shared_v"],
            ),
        )
        new_layers = {"mamba": m_new, "shared_k": k_new, "shared_v": v_new}

    x = L.norm(cfg, x, params.get("final_norm"))
    logits = shard(unembed(cfg, params, x), "btv")[:, 0]
    return logits, {"index": idx + 1, "layers": new_layers}


# ---------------------------------------------------------------------------
# Prefill: forward + cache construction
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    max_seq: int,
    *,
    impl: str = "xla",
    compute_dtype=jnp.bfloat16,
    cache_dtype=None,
) -> tuple[jax.Array, Params]:
    """Full-sequence prefill.  Returns (last-position logits [B, V], cache).
    ``cache_dtype`` stores the KV cache quantized (e.g. fp8)."""
    cache_dtype = cache_dtype or compute_dtype
    if inputs.dtype in (jnp.int32, jnp.int64):
        b, s = inputs.shape
        x = embed_tokens(cfg, params, inputs, compute_dtype)
    else:
        b, s, _ = inputs.shape
        x = inputs.astype(compute_dtype)
    cache = init_cache(cfg, b, max_seq, cache_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    def attn_prefill(lp, h):
        q, k, v = L._project_qkv(cfg, lp, h, positions)
        from repro.kernels import ops

        out = ops.attention(q, k, v, causal=True, impl=impl)
        mask = L.head_mask(cfg, out.dtype)
        if mask is not None:
            out = out * mask[None, None, :, None]
        return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), k, v

    pad_kv = lambda t: jnp.pad(t, ((0, 0), (0, max_seq - s), (0, 0), (0, 0)))

    if cfg.family in ("dense", "moe", "audio", "vlm"):

        def body(xc, lp):
            h = L.norm(cfg, xc, lp.get("ln1"))
            y, k, v = attn_prefill(lp["attn"], h)
            xc = xc + y
            h = L.norm(cfg, xc, lp.get("ln2"))
            if cfg.family == "moe":
                y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
            else:
                y2 = L.mlp_block(lp["ffn"], h)
            return xc + y2, (pad_kv(k).astype(cache_dtype),
                             pad_kv(v).astype(cache_dtype))

        x, (ks, vs) = jax.lax.scan(body, x, cast(params["layers"]))
        new_layers = {"k": ks, "v": vs}
    elif cfg.family == "ssm":

        def body(xc, lp):
            h = L.norm(cfg, xc, lp.get("ln"))
            # run block while capturing final state via the chunked scan
            y, st = _mamba1_with_state(cfg, lp["mixer"], h, impl)
            return xc + y, st

        x, new_layers = jax.lax.scan(body, x, cast(params["layers"]))
        new_layers = jax.tree.map(
            lambda a, proto: a.astype(proto.dtype),
            new_layers,
            init_cache(cfg, b, max_seq, cache_dtype)["layers"],
        )
    else:  # hybrid
        shared = cast(params["shared"])

        def cycle(xc, cyc_params):
            h = L.norm(cfg, xc, shared.get("ln1"))
            y, k, v = attn_prefill(shared["attn"], h)
            xc = xc + y
            h = L.norm(cfg, xc, shared.get("ln2"))
            xc = xc + L.mlp_block(shared["ffn"], h)

            def inner(xi, lp):
                hh = L.norm(cfg, xi, lp.get("ln"))
                yy, st = _mamba2_with_state(cfg, lp["mixer"], hh)
                return xi + yy, st

            xc, m_st = jax.lax.scan(inner, xc, cyc_params)
            return xc, (m_st, pad_kv(k).astype(cache_dtype),
                        pad_kv(v).astype(cache_dtype))

        x, (m_new, ks, vs) = jax.lax.scan(cycle, x, cast(params["layers"]))
        proto = init_cache(cfg, b, max_seq, cache_dtype)["layers"]["mamba"]
        m_new = jax.tree.map(lambda a, pr: a.astype(pr.dtype), m_new, proto)
        new_layers = {"mamba": m_new, "shared_k": ks, "shared_v": vs}

    x = L.norm(cfg, x, params.get("final_norm"))
    logits = shard(unembed(cfg, params, x[:, -1:, :]), "btv")[:, 0]
    return logits, {"index": jnp.int32(s), "layers": new_layers}


def _mamba1_with_state(cfg, p, x, impl):
    """mamba1_block but also returning the final SSM + conv state."""
    b, s, _ = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    conv_state = xi_raw[:, -(cfg.ssm_conv - 1):, :]
    xi = jax.nn.silu(SSM.causal_conv(xi_raw, p["conv_w"], p["conv_b"]))
    dbc = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt_r, B_, C_ = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, h_fin = SSM.selective_scan_chunked(
        xi.astype(jnp.float32), dt, B_.astype(jnp.float32), C_.astype(jnp.float32),
        A, h0, impl=impl,
    )
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xi
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), {
        "conv": conv_state, "h": h_fin,
    }


def _mamba2_with_state(cfg, p, x):
    from repro.models.layers import rms_norm

    b, s, _ = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj_zx"])
    z, xr = jnp.split(zx, 2, axis=-1)
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
    bc_raw, dt = jnp.split(bcdt, [2 * ds], axis=-1)
    conv_x_state = xr[:, -(cfg.ssm_conv - 1):, :]
    conv_bc_state = bc_raw[:, -(cfg.ssm_conv - 1):, :]
    xi = jax.nn.silu(SSM.causal_conv(xr, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(SSM.causal_conv(bc_raw, p["conv_bc_w"], p["conv_bc_b"]))
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, s, nh, hp).astype(jnp.float32)
    h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    y, h_fin = SSM.ssd_chunked(
        xh, dt, B_.astype(jnp.float32), C_.astype(jnp.float32), A, h0
    )
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), {
        "conv_x": conv_x_state, "conv_bc": conv_bc_state, "h": h_fin,
    }
