"""Decoder-only LM supporting all assigned families.

One parameter pytree, ``lax.scan`` over stacked layer weights (keeps HLO and
compile time depth-independent), three entry points:

  * ``forward``           -- train / full-sequence logits (tokens or
                             embeddings in)
  * ``prefill``           -- forward + build decode cache (bucket-padded
                             prompts via ``length``)
  * ``prefill_into_slot`` -- prefill one prompt straight into a batch cache
                             slot (jitted; no host-side cache splice)
  * ``decode_step``       -- one token with KV cache / SSM state
  * ``decode_loop``       -- k fused microsteps via lax.scan with per-slot
                             active masking (sync-free serving fast path)

Hybrid (Zamba2) runs an outer scan over cycles: one *shared* attention+MLP
block (single weight set) followed by ``shared_attn_every`` Mamba2 layers per
cycle.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.act_sharding import shard

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: dict = {}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        p["attn"] = L.init_attention(cfg, ks[0], cfg.d_model, dtype)
        if cfg.family == "moe":
            p["ffn"] = MOE.init_moe(cfg, ks[1], dtype)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.parametric_norm:
            p["ln1"] = jnp.ones((cfg.d_model,), dtype)
            p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.family == "ssm":
        p["mixer"] = SSM.init_mamba1(cfg, ks[0], dtype)
        if cfg.parametric_norm:
            p["ln"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.family == "hybrid":
        p["mixer"] = SSM.init_mamba2(cfg, ks[0], dtype)
        if cfg.parametric_norm:
            p["ln"] = jnp.ones((cfg.d_model,), dtype)
    else:
        raise ValueError(cfg.family)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
        * cfg.d_model**-0.5
    }
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_init_layer(cfg, keys[1 + i], dtype) for i in range(cfg.num_layers)],
    )
    if cfg.family == "hybrid":
        n_cyc = cfg.num_layers // cfg.shared_attn_every
        stacked = jax.tree.map(
            lambda x: x.reshape(n_cyc, cfg.shared_attn_every, *x.shape[1:]), stacked
        )
        kk = jax.random.split(keys[-1], 2)
        params["shared"] = {
            "attn": L.init_attention(cfg, kk[0], cfg.d_model, dtype),
            "ffn": L.init_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
        }
        if cfg.parametric_norm:
            params["shared"]["ln1"] = jnp.ones((cfg.d_model,), dtype)
            params["shared"]["ln2"] = jnp.ones((cfg.d_model,), dtype)
    params["layers"] = stacked
    if cfg.parametric_norm:
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size), dtype)
            * cfg.d_model**-0.5
        )
    return params


# ---------------------------------------------------------------------------
# Layer bodies (full sequence)
# ---------------------------------------------------------------------------


def _dense_layer(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln1"))
    x = x + L.attention_block(cfg, p["attn"], h, impl=impl)
    h = L.norm(cfg, x, p.get("ln2"))
    if cfg.family == "moe":
        y, aux, dropped = MOE.moe_block(cfg, p["ffn"], h)
        return x + y, aux, dropped
    return x + L.mlp_block(p["ffn"], h), jnp.float32(0), jnp.float32(0)


def _ssm_layer(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln"))
    if cfg.family == "hybrid":
        return x + SSM.mamba2_block(cfg, p["mixer"], h)
    return x + SSM.mamba1_block(cfg, p["mixer"], h, impl=impl)


def _shared_block(cfg: ModelConfig, p: Params, x: jax.Array, impl: str):
    h = L.norm(cfg, x, p.get("ln1"))
    x = x + L.attention_block(cfg, p["attn"], h, impl=impl)
    h = L.norm(cfg, x, p.get("ln2"))
    return x + L.mlp_block(p["ffn"], h)


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full": save nothing


# ---------------------------------------------------------------------------
# Forward (train / logits over full sequence)
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array, dtype):
    return params["embed"].astype(dtype)[tokens]


def unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    *,
    impl: str = "xla",
    remat_policy: str = "none",
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, dict]:
    """inputs: int tokens [B, S] or (embed_inputs archs) embeddings [B, S, d].
    Returns (logits [B, S, V], metrics)."""
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed_tokens(cfg, params, inputs, compute_dtype)
    else:
        assert cfg.embed_inputs, f"{cfg.name} does not take embedding inputs"
        x = inputs.astype(compute_dtype)
    x = shard(x, "btd")

    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    if cfg.family == "hybrid":
        shared = cast(params["shared"])

        def cycle(xc, cyc_params):
            xc = _shared_block(cfg, shared, xc, impl)

            def inner(xi, lp):
                return shard(_ssm_layer(cfg, lp, xi, impl), "btd"), None

            xc, _ = jax.lax.scan(inner, xc, cyc_params)
            return xc, None

        body = _maybe_remat(cycle, remat_policy)
        x, _ = jax.lax.scan(body, x, cast(params["layers"]))
        aux = dropped = jnp.float32(0)
    elif cfg.family == "ssm":

        def body(xc, lp):
            return shard(_ssm_layer(cfg, lp, xc, impl), "btd"), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat_policy), x, cast(params["layers"]))
        aux = dropped = jnp.float32(0)
    else:

        def body(xc, lp):
            xc, a, dr = _dense_layer(cfg, lp, xc, impl)
            return shard(xc, "btd"), (a, dr)

        x, (auxs, drops) = jax.lax.scan(
            _maybe_remat(body, remat_policy), x, cast(params["layers"])
        )
        aux, dropped = auxs.mean(), drops.mean()

    x = L.norm(cfg, x, params.get("final_norm"))
    logits = shard(unembed(cfg, params, x), "btv")
    return logits, {"moe_aux": aux, "moe_dropped": dropped}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    labels: jax.Array,
    *,
    impl: str = "xla",
    remat_policy: str = "none",
    compute_dtype=jnp.bfloat16,
    moe_aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    logits, metrics = forward(
        cfg, params, inputs, impl=impl, remat_policy=remat_policy,
        compute_dtype=compute_dtype,
    )
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    loss = ce + moe_aux_weight * metrics["moe_aux"]
    metrics = dict(metrics, ce=ce, loss=loss)
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> Params:
    l, hd = cfg.num_layers, cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        kv = lambda: jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, hd), dtype)
        layer_state = {"k": kv(), "v": kv()}
    elif cfg.family == "ssm":
        st = SSM.mamba1_init_state(cfg, batch, dtype)
        layer_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (l, *a.shape)), st
        )
    elif cfg.family == "hybrid":
        n_cyc = l // cfg.shared_attn_every
        st = SSM.mamba2_init_state(cfg, batch, dtype)
        layer_state = {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (n_cyc, cfg.shared_attn_every, *a.shape)
                ),
                st,
            ),
            "shared_k": jnp.zeros(
                (n_cyc, batch, max_seq, cfg.num_kv_heads, hd), dtype
            ),
            "shared_v": jnp.zeros(
                (n_cyc, batch, max_seq, cfg.num_kv_heads, hd), dtype
            ),
        }
    else:
        raise ValueError(cfg.family)
    return {"index": jnp.int32(0), "layers": layer_state}


def init_paged_cache(
    cfg: ModelConfig,
    batch: int,
    num_pages: int,
    page_size: int,
    max_pages_per_slot: int,
    dtype=jnp.bfloat16,
) -> Params:
    """Paged decode cache: physical page pools + per-slot block tables.

    ``layers.k/v`` are [L, P, page, kvH, hd] pools of physical pages shared
    across slots (prefix-shared pages appear in several block tables);
    ``block_tables`` is [B, W] int32 with ``W = max_pages_per_slot + 1`` —
    the extra last column stays permanently at the sentinel page 0 so
    overflow writes clamp onto a page nobody reads (``L.paged_kv_write``).
    Attention families only: SSM/hybrid state is O(1) per slot and keeps the
    dense layout."""
    assert cfg.family in ("dense", "moe", "audio", "vlm"), (
        f"paged KV cache is for attention families, not {cfg.family!r}"
    )
    l, hd = cfg.num_layers, cfg.resolved_head_dim
    kv = lambda: jnp.zeros(
        (l, num_pages, page_size, cfg.num_kv_heads, hd), dtype
    )
    return {
        "index": jnp.zeros((batch,), jnp.int32),
        "block_tables": jnp.zeros((batch, max_pages_per_slot + 1), jnp.int32),
        "layers": {"k": kv(), "v": kv()},
    }


def is_paged_cache(cache: Params) -> bool:
    return isinstance(cache, dict) and "block_tables" in cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    cache: Params,
    *,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
) -> tuple[jax.Array, Params]:
    """tokens: [B] int32 (last generated).  Returns (logits [B, V], cache).

    ``cache["index"]`` may be scalar (uniform batch) or [B] per-slot
    positions (continuous batching).  ``attn_impl`` picks the decode
    attention core (see ``ops.decode_attention``)."""
    x = params["embed"].astype(compute_dtype)[tokens][:, None, :]  # [B, 1, d]
    idx = cache["index"]
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        bt = cache.get("block_tables")  # paged cache: [B, W] page map

        def body(xc, per_layer):
            lp, k_c, v_c = per_layer
            h = L.norm(cfg, xc, lp.get("ln1"))
            if bt is not None:
                y, (k_c, v_c) = L.attention_decode_paged(
                    cfg, lp["attn"], h, (k_c, v_c), bt, idx, impl=attn_impl
                )
            else:
                y, (k_c, v_c) = L.attention_decode(
                    cfg, lp["attn"], h, (k_c, v_c), idx, impl=attn_impl
                )
            xc = xc + y
            h = L.norm(cfg, xc, lp.get("ln2"))
            if cfg.family == "moe":
                y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
            else:
                y2 = L.mlp_block(lp["ffn"], h)
            return xc + y2, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (cast(params["layers"]), cache["layers"]["k"], cache["layers"]["v"])
        )
        new_layers = {"k": k_new, "v": v_new}
    elif cfg.family == "ssm":

        def body(xc, per_layer):
            lp, st = per_layer
            h = L.norm(cfg, xc, lp.get("ln"))
            y, st = SSM.mamba1_step(cfg, lp["mixer"], h[:, 0], st)
            return xc + y[:, None], st

        x, new_layers = jax.lax.scan(
            body, x, (cast(params["layers"]), cache["layers"])
        )
    else:  # hybrid
        shared = cast(params["shared"])

        def cycle(xc, per_cycle):
            cyc_params, mamba_st, k_c, v_c = per_cycle
            h = L.norm(cfg, xc, shared.get("ln1"))
            y, (k_c, v_c) = L.attention_decode(
                cfg, shared["attn"], h, (k_c, v_c), idx, impl=attn_impl
            )
            xc = xc + y
            h = L.norm(cfg, xc, shared.get("ln2"))
            xc = xc + L.mlp_block(shared["ffn"], h)

            def inner(xi, per_layer):
                lp, st = per_layer
                hh = L.norm(cfg, xi, lp.get("ln"))
                yy, st = SSM.mamba2_step(cfg, lp["mixer"], hh[:, 0], st)
                return xi + yy[:, None], st

            xc, mamba_st = jax.lax.scan(inner, xc, (cyc_params, mamba_st))
            return xc, (mamba_st, k_c, v_c)

        x, (m_new, k_new, v_new) = jax.lax.scan(
            cycle,
            x,
            (
                cast(params["layers"]),
                cache["layers"]["mamba"],
                cache["layers"]["shared_k"],
                cache["layers"]["shared_v"],
            ),
        )
        new_layers = {"mamba": m_new, "shared_k": k_new, "shared_v": v_new}

    x = L.norm(cfg, x, params.get("final_norm"))
    logits = shard(unembed(cfg, params, x), "btv")[:, 0]
    new_cache = dict(cache, index=idx + 1, layers=new_layers)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunk-verify decode (speculative decoding target pass)
# ---------------------------------------------------------------------------


def recurrent_state_batch_axis(cfg: ModelConfig) -> int:
    """Batch-axis position inside the *recurrent* per-layer state pytree
    (``chunk_states`` leaves carry one extra leading step axis on top)."""
    return 2 if cfg.family == "hybrid" else 1


def chunk_recurrent_states(cfg: ModelConfig, layers: Params) -> Optional[Params]:
    """The rollback-relevant slice of a cache's ``layers`` pytree: SSM/conv
    state for recurrent families, ``None`` for pure-KV families (their
    rollback is an index rewind — stale entries are overwritten before ever
    being read, DESIGN.md §4)."""
    if cfg.family == "ssm":
        return layers
    if cfg.family == "hybrid":
        return layers["mamba"]
    return None


def merge_recurrent_states(cfg: ModelConfig, layers: Params, states) -> Params:
    """Inverse of ``chunk_recurrent_states``: graft rolled-back recurrent
    state back into a cache's ``layers`` pytree."""
    if cfg.family == "ssm":
        return states
    if cfg.family == "hybrid":
        return dict(layers, mamba=states)
    return layers


def decode_chunk(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    cache: Params,
    *,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
    logits_at: Optional[jax.Array] = None,
    anc: Optional[jax.Array] = None,
    depths: Optional[jax.Array] = None,
) -> tuple[jax.Array, Params, Optional[Params]]:
    """Score a T = gamma+1 speculative chunk in ONE fused pass.

    tokens: [B, T] int32 — current token + gamma draft tokens per slot.
    Returns ``(logits [B, T, V], cache, chunk_states)`` with the cache index
    advanced by T and the cache's K/V (or SSM state) consumed.

    Tree mode (attention families only): ``anc`` [B, T] int32 ancestor
    bitmasks + ``depths`` [T] int32 per-node depths switch the attention
    core to ``tree_verify_attention`` — tokens then hold one packed-tree
    node each (node 0 = root = the current token) and every layer applies
    the same ancestor visibility and depth-based RoPE.  ``None`` (default)
    is bit-identical to the linear-chunk path.

    ``logits_at`` ([] int32, traced) restricts the unembedding to one chunk
    position — logits come back [B, 1, V].  Chunk-based suffix prefill
    needs only the last real position's logits, and the vocab projection
    over a full pad bucket would otherwise dominate its cost.

    Attention families score all T positions in parallel through
    ``attention_verify`` (the chunk-verify kernel path) — no sequential
    scan, so the pass costs one cache sweep instead of T.  Recurrent
    families (ssm/hybrid) cannot parallelize the state recurrence; they run
    a ``lax.scan`` of ``decode_step`` *inside the same jitted program* and
    additionally return ``chunk_states``: the recurrent per-layer state
    stacked after each chunk step (leading axis T), which acceptance uses to
    rewind a slot's SSM/conv state past rejected tokens
    (``spec.rollback.select_step_state``).  Pure-KV families return ``None``
    there — rewinding ``index`` alone is a complete rollback for them."""
    b, t = tokens.shape
    if anc is not None and cfg.family not in ("dense", "moe", "audio", "vlm"):
        raise ValueError(
            f"tree verification needs an attention family, got {cfg.family!r}"
        )
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        x = params["embed"].astype(compute_dtype)[tokens]  # [B, T, d]
        idx = cache["index"]
        bt = cache.get("block_tables")  # paged cache: [B, W] page map
        cast = lambda tr: jax.tree.map(
            lambda a: a.astype(compute_dtype)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, tr)

        def body(xc, per_layer):
            lp, k_c, v_c = per_layer
            h = L.norm(cfg, xc, lp.get("ln1"))
            if bt is not None:
                y, (k_c, v_c) = L.attention_verify_paged(
                    cfg, lp["attn"], h, (k_c, v_c), bt, idx, impl=attn_impl,
                    anc=anc, depths=depths,
                )
            else:
                y, (k_c, v_c) = L.attention_verify(
                    cfg, lp["attn"], h, (k_c, v_c), idx, impl=attn_impl,
                    anc=anc, depths=depths,
                )
            xc = xc + y
            h = L.norm(cfg, xc, lp.get("ln2"))
            if cfg.family == "moe":
                y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
            else:
                y2 = L.mlp_block(lp["ffn"], h)
            return xc + y2, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x,
            (cast(params["layers"]), cache["layers"]["k"], cache["layers"]["v"]),
        )
        x = L.norm(cfg, x, params.get("final_norm"))
        if logits_at is not None:
            x = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(logits_at, jnp.int32), 1, axis=1
            )
        logits = shard(unembed(cfg, params, x), "btv")
        new_cache = dict(
            cache, index=idx + t, layers={"k": k_new, "v": v_new}
        )
        return logits, new_cache, None

    # Recurrent families: fused sequential scan with per-step state capture.
    def step(c, tok_t):
        logits_t, c = decode_step(
            cfg, params, tok_t, c, compute_dtype=compute_dtype,
            attn_impl=attn_impl,
        )
        return c, (logits_t, chunk_recurrent_states(cfg, c["layers"]))

    cache, (logits_seq, states_seq) = jax.lax.scan(step, cache, tokens.T)
    logits = logits_seq.transpose(1, 0, 2)
    if logits_at is not None:
        logits = jax.lax.dynamic_slice_in_dim(
            logits, jnp.asarray(logits_at, jnp.int32), 1, axis=1
        )
    return logits, cache, states_seq


# ---------------------------------------------------------------------------
# Fused decode loop (sync-free serving fast path)
# ---------------------------------------------------------------------------


def decode_loop(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    cache: Params,
    remaining: Optional[jax.Array] = None,
    *,
    k: int,
    max_seq: Optional[int] = None,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
) -> tuple[jax.Array, Params, jax.Array, jax.Array, jax.Array]:
    """Run ``k`` greedy decode microsteps entirely on-device via ``lax.scan``.

    ``remaining``: [B] int32 per-slot token budgets.  A slot is *active* while
    ``remaining > 0`` and (when ``max_seq`` is set) its cache index is below
    ``max_seq - 1``.  Inactive slots are frozen in place — token, cache index,
    and budget untouched — so finished requests never need a host round-trip
    mid-loop.  ``remaining=None`` runs all slots unconditionally (uniform
    batch; used by the fused collocated train+decode step, where the cache
    index may be scalar).

    Returns ``(tokens, cache, remaining, toks_seq, steps, bad)`` where
    ``toks_seq[j]`` is the [B] token vector after microstep ``j`` (frozen
    slots repeat their last token), ``steps[i]`` counts microsteps slot
    ``i`` was active for, and ``bad[i]`` is the per-slot NaN screen
    (DESIGN.md §9): True if any microstep produced a non-finite logit for
    an *active* slot ``i`` — its tokens from this loop are garbage and the
    caller must quarantine the slot instead of absorbing them.  Inactive
    slots are never flagged (an empty slot's logits are unread noise).
    The caller fetches everything it needs with ONE device->host transfer
    after the loop.
    """
    b = tokens.shape[0]
    masked = remaining is not None

    def body(carry, _):
        toks, c, rem, bad = carry
        idx = c["index"]
        logits, new_c = decode_step(
            cfg, params, toks, c, compute_dtype=compute_dtype,
            attn_impl=attn_impl,
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        finite = jnp.isfinite(logits).all(axis=-1)
        if masked:
            active = rem > 0
            if max_seq is not None:
                active = active & (idx < max_seq - 1)
            toks = jnp.where(active, next_tok, toks)
            # dict(new_c, ...) keeps cache keys beyond index/layers (the
            # paged cache's block_tables) flowing through the scan carry
            c = dict(new_c, index=jnp.where(active, new_c["index"], idx))
            rem = jnp.where(active, rem - 1, rem)
        else:
            toks, c = next_tok, new_c
            active = jnp.ones((b,), bool)
        bad = bad | (active & ~finite)
        return (toks, c, rem, bad), (toks, active)

    rem0 = remaining if masked else jnp.zeros((b,), jnp.int32)
    bad0 = jnp.zeros((b,), bool)
    (tokens, cache, rem, bad), (toks_seq, active_seq) = jax.lax.scan(
        body, (tokens, cache, rem0, bad0), None, length=k
    )
    steps = active_seq.sum(axis=0).astype(jnp.int32) if k else jnp.zeros(
        (b,), jnp.int32
    )
    return tokens, cache, rem, toks_seq, steps, bad


# ---------------------------------------------------------------------------
# Prefill: forward + cache construction
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    max_seq: int,
    *,
    impl: str = "xla",
    compute_dtype=jnp.bfloat16,
    cache_dtype=None,
    length: Optional[jax.Array] = None,
) -> tuple[jax.Array, Params]:
    """Full-sequence prefill.  Returns (last-position logits [B, V], cache).
    ``cache_dtype`` stores the KV cache quantized (e.g. fp8).

    ``length`` (traced [] int32) marks the true prompt length when ``inputs``
    is zero-padded to a compile bucket: logits are taken at ``length - 1`` and
    the cache index starts at ``length``.  Pad positions only ever produce
    K/V entries *beyond* the cache index, which decode overwrites before
    reading (see DESIGN.md §3), so padding never leaks into results."""
    cache_dtype = cache_dtype or compute_dtype
    if inputs.dtype in (jnp.int32, jnp.int64):
        b, s = inputs.shape
        x = embed_tokens(cfg, params, inputs, compute_dtype)
    else:
        b, s, _ = inputs.shape
        x = inputs.astype(compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1 else a, t)

    def attn_prefill(lp, h):
        q, k, v = L._project_qkv(cfg, lp, h, positions)
        from repro.kernels import ops

        out = ops.attention(q, k, v, causal=True, impl=impl)
        mask = L.head_mask(cfg, out.dtype)
        if mask is not None:
            out = out * mask[None, None, :, None]
        return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]), k, v

    pad_kv = lambda t: jnp.pad(t, ((0, 0), (0, max_seq - s), (0, 0), (0, 0)))

    if cfg.family in ("dense", "moe", "audio", "vlm"):

        def body(xc, lp):
            h = L.norm(cfg, xc, lp.get("ln1"))
            y, k, v = attn_prefill(lp["attn"], h)
            xc = xc + y
            h = L.norm(cfg, xc, lp.get("ln2"))
            if cfg.family == "moe":
                y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
            else:
                y2 = L.mlp_block(lp["ffn"], h)
            return xc + y2, (pad_kv(k).astype(cache_dtype),
                             pad_kv(v).astype(cache_dtype))

        x, (ks, vs) = jax.lax.scan(body, x, cast(params["layers"]))
        new_layers = {"k": ks, "v": vs}
    elif cfg.family == "ssm":

        def body(xc, lp):
            h = L.norm(cfg, xc, lp.get("ln"))
            # run block while capturing final state via the chunked scan
            y, st = _mamba1_with_state(cfg, lp["mixer"], h, impl, length=length)
            return xc + y, st

        x, new_layers = jax.lax.scan(body, x, cast(params["layers"]))
        new_layers = jax.tree.map(
            lambda a, proto: a.astype(proto.dtype),
            new_layers,
            init_cache(cfg, b, max_seq, cache_dtype)["layers"],
        )
    else:  # hybrid
        shared = cast(params["shared"])

        def cycle(xc, cyc_params):
            h = L.norm(cfg, xc, shared.get("ln1"))
            y, k, v = attn_prefill(shared["attn"], h)
            xc = xc + y
            h = L.norm(cfg, xc, shared.get("ln2"))
            xc = xc + L.mlp_block(shared["ffn"], h)

            def inner(xi, lp):
                hh = L.norm(cfg, xi, lp.get("ln"))
                yy, st = _mamba2_with_state(cfg, lp["mixer"], hh, length=length)
                return xi + yy, st

            xc, m_st = jax.lax.scan(inner, xc, cyc_params)
            return xc, (m_st, pad_kv(k).astype(cache_dtype),
                        pad_kv(v).astype(cache_dtype))

        x, (m_new, ks, vs) = jax.lax.scan(cycle, x, cast(params["layers"]))
        proto = init_cache(cfg, b, max_seq, cache_dtype)["layers"]["mamba"]
        m_new = jax.tree.map(lambda a, pr: a.astype(pr.dtype), m_new, proto)
        new_layers = {"mamba": m_new, "shared_k": ks, "shared_v": vs}

    x = L.norm(cfg, x, params.get("final_norm"))
    if length is None:
        last, index = x[:, -1:, :], jnp.int32(s)
    else:
        index = jnp.asarray(length, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(x, index - 1, 1, axis=1)
    logits = shard(unembed(cfg, params, last), "btv")[:, 0]
    return logits, {"index": index, "layers": new_layers}


def _ssm_tail_state(x, length, n):
    """Last ``n`` timesteps before ``length`` with implicit left zero-pad —
    the decode conv state for a bucket-padded prompt of true ``length``."""
    if length is None:
        return x[:, -n:, :]
    xp = jnp.pad(x, ((0, 0), (n, 0), (0, 0)))
    return jax.lax.dynamic_slice_in_dim(
        xp, jnp.asarray(length, jnp.int32), n, axis=1
    )


def _ssm_dt_mask(dt, length):
    """Zero the SSM step size at pad positions (>= length): ``dt == 0`` makes
    the recurrence a no-op (decay exp(0*A) == 1, input term 0), so a bucket-
    padded prompt leaves the state exactly where the real tokens left it."""
    if length is None:
        return dt
    valid = jnp.arange(dt.shape[1]) < jnp.asarray(length, jnp.int32)
    return dt * valid[None, :, None]


def _mamba1_with_state(cfg, p, x, impl, length=None):
    """mamba1_block but also returning the final SSM + conv state."""
    b, s, _ = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    conv_state = _ssm_tail_state(xi_raw, length, cfg.ssm_conv - 1)
    xi = jax.nn.silu(SSM.causal_conv(xi_raw, p["conv_w"], p["conv_b"]))
    dbc = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt_r, B_, C_ = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)
    dt = _ssm_dt_mask(dt, length)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, h_fin = SSM.selective_scan_chunked(
        xi.astype(jnp.float32), dt, B_.astype(jnp.float32), C_.astype(jnp.float32),
        A, h0, impl=impl,
    )
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xi
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), {
        "conv": conv_state, "h": h_fin,
    }


def prefill_into_slot(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    length: jax.Array,
    slot: jax.Array,
    cache: Params,
    *,
    max_seq: int,
    impl: str = "xla",
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    """Prefill one bucket-padded prompt and write its K/V (or SSM state)
    directly into the batch decode cache at ``slot`` — one jitted program,
    no host-side cache splice.

    inputs: [1, S_bucket] int32 tokens (or [1, S_bucket, d] embeddings),
    zero-padded to a power-of-two bucket; length: [] int32 true prompt
    length; slot: [] int32 target batch slot (traced, so one compiled
    program serves every slot).  ``cache`` should be donated by the caller's
    jit so the slot write is performed in place.

    Returns ``(first generated token [] int32, updated batch cache)``.
    """
    logits, cache1 = prefill(
        cfg, params, inputs, max_seq, impl=impl, compute_dtype=compute_dtype,
        cache_dtype=jax.tree.leaves(cache["layers"])[0].dtype, length=length,
    )
    tok = jnp.argmax(logits[0]).astype(jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)

    def upd(axis):
        return lambda b, s: jax.lax.dynamic_update_index_in_dim(
            b, jnp.squeeze(s, axis).astype(b.dtype), slot, axis=axis
        )

    # Batch axis is 1 for [L, B, ...] leaves; the hybrid family's per-cycle
    # mamba state is [n_cyc, shared_attn_every, B, ...] — batch on axis 2.
    if cfg.family == "hybrid":
        new_layers = {
            "mamba": jax.tree.map(
                upd(2), cache["layers"]["mamba"], cache1["layers"]["mamba"]
            ),
            "shared_k": upd(1)(
                cache["layers"]["shared_k"], cache1["layers"]["shared_k"]
            ),
            "shared_v": upd(1)(
                cache["layers"]["shared_v"], cache1["layers"]["shared_v"]
            ),
        }
    else:
        new_layers = jax.tree.map(
            upd(1), cache["layers"], cache1["layers"]
        )
    index = cache["index"].at[slot].set(jnp.asarray(length, jnp.int32))
    return tok, {"index": index, "layers": new_layers}


def prefill_into_slot_paged(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    length: jax.Array,
    slot: jax.Array,
    cache: Params,
    *,
    impl: str = "xla",
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    """Cold-path prefill straight into the paged pool.

    Runs the ordinary full-sequence prefill over the [1, S_bucket] prompt —
    against a *bucket-sized* scratch cache rather than a dense max_seq row —
    then scatters the K/V bucket page-by-page into the slot's block-table
    pages.  The bucket must be page-aligned (the engine raises its minimum
    prefill bucket to the page size).  Bucket-pad positions past ``length``
    scatter into either the slot's last page beyond ``index`` (stale,
    overwritten before read) or unallocated table entries, which hold the
    sentinel page — a write sink nobody attends to.

    Returns ``(first generated token [] int32, updated paged cache)``."""
    k_pool = cache["layers"]["k"]  # [L, P, page, kvH, hd]
    page = k_pool.shape[2]
    sb = inputs.shape[1]
    assert sb % page == 0, f"prefill bucket {sb} not page-aligned ({page})"
    nbp = sb // page
    logits, cache1 = prefill(
        cfg, params, inputs, sb, impl=impl, compute_dtype=compute_dtype,
        cache_dtype=k_pool.dtype, length=length,
    )
    tok = jnp.argmax(logits[0]).astype(jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    pages = jax.lax.dynamic_slice(
        cache["block_tables"], (slot, 0), (1, nbp)
    )[0]  # [nbp] physical page per bucket page

    def scatter(pool, new):  # new: [L, 1, SB, kvH, hd]
        l = pool.shape[0]
        newp = new[:, 0].reshape(l, nbp, page, *pool.shape[3:])
        return pool.at[:, pages].set(newp.astype(pool.dtype))

    new_layers = {
        "k": scatter(cache["layers"]["k"], cache1["layers"]["k"]),
        "v": scatter(cache["layers"]["v"], cache1["layers"]["v"]),
    }
    index = cache["index"].at[slot].set(jnp.asarray(length, jnp.int32))
    return tok, dict(cache, index=index, layers=new_layers)


def prefill_chunks_into_slots(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    chunk_lens: jax.Array,
    cache: Params,
    *,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
    need_logits: bool = True,
) -> tuple[jax.Array, Params]:
    """One unified chunked-prefill microstep over ALL slots (DESIGN.md §7).

    tokens: [B, C] int32 — one fixed-width prompt chunk per slot,
    zero-padded past ``chunk_lens``; chunk_lens: [B] int32 real tokens per
    slot (ragged: 0 freezes a slot — no K/V write, no index advance);
    cache: the batch decode cache (dense rows or paged pool) with
    ``index`` [B] holding each slot's prefill progress.  Because every
    quantity is traced, ONE compiled program serves every mix of slots,
    chunk lengths, and prefill offsets — this is the program that replaces
    the power-of-two prefill bucket zoo.

    Each layer writes the chunk's real K/V at ``index .. index +
    chunk_lens - 1`` and attends it to the previously-written prefix
    (radix-shared pages included, so prefix hits compose with chunking for
    free) plus the chunk's own causal triangle; ``index`` advances by
    ``chunk_lens`` per slot.

    Returns ``(next_tokens [B] int32, cache)``: ``next_tokens[b]`` is the
    argmax over the logits at chunk position ``chunk_lens[b] - 1`` — the
    model's next-token prediction after the chunk, meaningful only for the
    chunk that completes a slot's prompt (the engine fetches it exactly
    then).  ``need_logits=False`` (draft-model prefill, whose first-token
    logits are never read) skips the vocab projection entirely.

    Attention families only: recurrent (ssm/hybrid) prefill keeps the
    monolithic dt-masked bucket path — their state recurrence cannot skip
    ahead chunk-by-chunk without carrying per-chunk state host-side."""
    assert cfg.family in ("dense", "moe", "audio", "vlm"), (
        f"chunked prefill needs an attention family, not {cfg.family!r}"
    )
    b, c = tokens.shape
    x = embed_tokens(cfg, params, tokens, compute_dtype)  # [B, C, d]
    idx = cache["index"]
    lens = jnp.asarray(chunk_lens, jnp.int32)
    bt = cache.get("block_tables")  # paged cache: [B, W] page map
    cast = lambda tr: jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if a.dtype == jnp.float32 and a.ndim > 1 else a, tr)

    def body(xc, per_layer):
        lp, k_c, v_c = per_layer
        h = L.norm(cfg, xc, lp.get("ln1"))
        if bt is not None:
            y, (k_c, v_c) = L.attention_prefill_chunk_paged(
                cfg, lp["attn"], h, (k_c, v_c), bt, idx, lens,
                impl=attn_impl,
            )
        else:
            y, (k_c, v_c) = L.attention_prefill_chunk(
                cfg, lp["attn"], h, (k_c, v_c), idx, lens, impl=attn_impl
            )
        xc = xc + y
        h = L.norm(cfg, xc, lp.get("ln2"))
        if cfg.family == "moe":
            y2, _, _ = MOE.moe_block(cfg, lp["ffn"], h)
        else:
            y2 = L.mlp_block(lp["ffn"], h)
        return xc + y2, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x,
        (cast(params["layers"]), cache["layers"]["k"], cache["layers"]["v"]),
    )
    index = idx + lens
    new_cache = dict(cache, index=index, layers={"k": k_new, "v": v_new})
    if not need_logits:
        return jnp.zeros((b,), jnp.int32), new_cache
    x = L.norm(cfg, x, params.get("final_norm"))
    # per-slot last real chunk position (frozen slots clamp to row 0 and
    # produce garbage nobody fetches)
    pos = jnp.maximum(lens - 1, 0)[:, None, None]
    last = jnp.take_along_axis(x, pos, axis=1)  # [B, 1, d]
    logits = shard(unembed(cfg, params, last), "btv")[:, 0]
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, new_cache


def prefill_suffix_into_slot(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    suffix_len: jax.Array,
    shared_len: jax.Array,
    slot: jax.Array,
    cache: Params,
    *,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
) -> tuple[jax.Array, Params]:
    """Prefix-hit prefill: score only the prompt *suffix* against shared
    prefix pages already resident in the pool.

    tokens: [1, T_bucket] int32 suffix tokens zero-padded to a compile
    bucket; suffix_len: [] int32 true suffix length; shared_len: [] int32
    prefix length served from the radix cache (a page multiple, >= 1 page);
    slot: [] int32 target slot whose block table already maps the shared
    pages (refcounted) plus freshly-allocated suffix pages.

    The heavy lifting is ``decode_chunk`` on a one-row view of the paged
    cache: the chunk-verify path attends suffix queries to the shared
    prefix plus the chunk's own causal triangle and scatters suffix K/V into
    the slot's private pages — so prefill compute is O(suffix), ZERO FLOPs
    for the shared length.  Bucket-pad rows write stale/sentinel K/V and
    attend garbage, but the returned logits row ``suffix_len - 1`` attends
    real positions only.

    Returns ``(first generated token [] int32, updated paged cache)``."""
    slot = jnp.asarray(slot, jnp.int32)
    shared = jnp.asarray(shared_len, jnp.int32)
    row = jax.lax.dynamic_slice_in_dim(
        cache["block_tables"], slot, 1, axis=0
    )  # [1, W]
    view = {
        "index": shared[None],
        "block_tables": row,
        "layers": cache["layers"],
    }
    pos = jnp.asarray(suffix_len, jnp.int32) - 1
    logits, view, _ = decode_chunk(
        cfg, params, tokens, view, compute_dtype=compute_dtype,
        attn_impl=attn_impl, logits_at=pos,
    )
    tok = jnp.argmax(logits[0, 0]).astype(jnp.int32)
    index = cache["index"].at[slot].set(shared + suffix_len)
    return tok, dict(cache, index=index, layers=view["layers"])


def _mamba2_with_state(cfg, p, x, length=None):
    from repro.models.layers import rms_norm

    b, s, _ = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj_zx"])
    z, xr = jnp.split(zx, 2, axis=-1)
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
    bc_raw, dt = jnp.split(bcdt, [2 * ds], axis=-1)
    conv_x_state = _ssm_tail_state(xr, length, cfg.ssm_conv - 1)
    conv_bc_state = _ssm_tail_state(bc_raw, length, cfg.ssm_conv - 1)
    xi = jax.nn.silu(SSM.causal_conv(xr, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(SSM.causal_conv(bc_raw, p["conv_bc_w"], p["conv_bc_b"]))
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = _ssm_dt_mask(dt, length)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, s, nh, hp).astype(jnp.float32)
    h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    y, h_fin = SSM.ssd_chunked(
        xh, dt, B_.astype(jnp.float32), C_.astype(jnp.float32), A, h0
    )
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), {
        "conv_x": conv_x_state, "conv_bc": conv_bc_state, "h": h_fin,
    }
