from repro.data.pipeline import SyntheticDataset, make_train_iterator

__all__ = ["SyntheticDataset", "make_train_iterator"]
