"""Deterministic synthetic LM data pipeline.

Produces a learnable, Zipf-distributed token stream with short-range
structure (next token correlated with current), so training loss measurably
drops — the end-to-end examples assert on that.  The pipeline is:

  * host-sharded: each host materializes only its slice of the global batch
  * stateful + restorable: ``state()``/``restore()`` round-trips through the
    checkpointer so a resumed job sees the exact same batch sequence
  * modality-aware: ``embed_inputs`` archs get (embeddings, labels) pairs
    from the stub frontend (DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    _step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count
        v = self.cfg.vocab_size
        rng = np.random.default_rng(self.seed)
        # Fixed Zipf unigram table + a sticky bigram successor table: token t
        # is followed by succ[t] w.p. 0.5, else a fresh Zipf draw.
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._succ = rng.integers(0, v, size=v, dtype=np.int64)

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.host_index
        )

    def _sample_tokens(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        s = self.seq_len + 1
        fresh = rng.choice(
            self.cfg.vocab_size, size=(batch, s), p=self._unigram
        ).astype(np.int64)
        sticky = rng.random((batch, s)) < 0.5
        toks = fresh.copy()
        for t in range(1, s):
            toks[:, t] = np.where(sticky[:, t], self._succ[toks[:, t - 1]], fresh[:, t])
        return toks

    def next_batch(self) -> dict:
        rng = self._rng_for(self._step)
        self._step += 1
        toks = self._sample_tokens(rng, self.local_batch)
        inputs, labels = toks[:, :-1], toks[:, 1:]
        batch = {"labels": labels.astype(np.int32)}
        if self.cfg.embed_inputs:
            # Stub modality frontend: deterministic per-token embedding table
            # (stand-in for EnCodec frames / ViT patches).
            d = self.cfg.d_model
            table_rng = np.random.default_rng(self.seed + 7)
            table = table_rng.standard_normal(
                (min(self.cfg.vocab_size, 4096), d)
            ).astype(np.float32) * 0.02
            batch["inputs"] = table[inputs % table.shape[0]]
        else:
            batch["inputs"] = inputs.astype(np.int32)
        return batch

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step}

    def restore(self, state: dict) -> None:
        self._step = int(state["step"])


def make_train_iterator(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    host_index: int = 0,
    host_count: int = 1,
    seed: int = 0,
):
    ds = SyntheticDataset(
        cfg,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        host_index=host_index,
        host_count=host_count,
        seed=seed,
    )

    def it():
        while True:
            yield ds.next_batch()

    return ds, it()
