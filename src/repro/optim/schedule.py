"""Learning-rate schedules (warmup + cosine/linear/constant decay)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_schedule(cfg: TrainConfig):
    peak, warm, total = cfg.learning_rate, cfg.warmup_steps, cfg.total_steps

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm_frac = jnp.minimum(step / jnp.maximum(warm, 1), 1.0)
        decay_steps = jnp.maximum(total - warm, 1)
        t = jnp.clip((step - warm) / decay_steps, 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            decay = 1.0 - t
        else:
            decay = jnp.ones_like(t)
        return peak * warm_frac * decay

    return schedule
