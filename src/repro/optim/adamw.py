"""AdamW in pure JAX over arbitrary parameter pytrees (fp32 moments)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, *, lr, cfg: TrainConfig):
    """One AdamW step.  ``lr`` may be a traced scalar (schedule value)."""
    step = opt_state["step"] + 1
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps) + wd * p.astype(jnp.float32)
        return mu, nu, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat = jax.tree.map(upd, grads, opt_state["mu"], opt_state["nu"], params)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"mu": mu, "nu": nu, "step": step}
