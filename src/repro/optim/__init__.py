from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compression import ef_int8_compress_decompress
from repro.optim.schedule import make_schedule

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "make_schedule",
    "ef_int8_compress_decompress",
]
