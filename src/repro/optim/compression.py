"""int8 error-feedback gradient compression for scarce cross-pod links.

Distributed-optimization trick (DESIGN.md §7.4): gradients crossing the
``pod`` axis are quantized to int8 with a per-leaf scale before the
all-gather+local-reduce exchange; the quantization residual is carried in an
error-feedback buffer and added to the next step's gradient, which keeps SGD
convergence (Karimireddy et al., EF-SGD).  Traffic on the pod links drops
~4x vs fp32 all-reduce (validated by the §Perf HLO byte counts).

Used inside shard_map over the compressed axis; other axes keep exact psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_compress_decompress(g: jax.Array, err: jax.Array):
    """Local quantize/dequantize with error feedback (no collective).

    Returns (dequantized gradient, new error buffer).  Composable with any
    reduction: callers all-gather the int8 payload + scale instead of fp32.
    """
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize(g32)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """int8 EF exchange over ``axis_name`` (call inside shard_map).

    all-gathers the int8 payload + per-shard scale and reduces locally:
    link bytes ~= size/4 * (n-1)/n vs fp32 all-reduce's ~2*size*(n-1)/n.
    """
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize(g32)
    deq_local = q.astype(jnp.float32) * scale
    new_err = g32 - deq_local
    qs = jax.lax.all_gather(q, axis_name)  # [n, ...] int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)  # [n]
    summed = jnp.tensordot(
        scales, qs.astype(jnp.float32), axes=((0,), (0,))
    )
    return summed, new_err
