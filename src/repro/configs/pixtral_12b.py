"""pixtral-12b — pixtral-ViT + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409; unverified].

40 layers, d_model=5120, 32H GQA (kv=8), d_ff=14336, vocab=131072.  The ViT
patch frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings of width d_model (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    embed_inputs=True,
)
