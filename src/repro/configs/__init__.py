"""Architecture / shape registry.

Public ids use dashes (``--arch qwen2-7b``); modules use underscores.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    SpecDecodeConfig,
    SpecInFConfig,
    TrainConfig,
    draft_config,
    mesh_axes,
    shape_applicable,
)

_ARCH_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-1.7b": "qwen3_1p7b",
    "olmo-1b": "olmo_1b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "musicgen-large": "musicgen_large",
    "pixtral-12b": "pixtral_12b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    if shape not in SHAPES:
        raise KeyError(f"unknown shape {shape!r}; known: {sorted(SHAPES)}")
    return SHAPES[shape]


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name, applicable, reason) for the 40-cell matrix."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch, shape.name, ok, reason


# ---------------------------------------------------------------------------
# Reduced smoke configs: same family/block layout, tiny dims, CPU-runnable.
# ---------------------------------------------------------------------------


def smoke_config(arch: str) -> ModelConfig:
    full = get_config(arch)
    reduced = dict(
        name=full.name + "-smoke",
        num_layers=2 if full.family != "hybrid" else 4,
        d_model=64,
        d_ff=128 if full.d_ff else 0,
        vocab_size=256,
        head_dim=16 if full.num_heads else 0,
        rope_theta=full.rope_theta,
    )
    if full.num_heads:
        reduced["num_heads"] = 4
        reduced["num_kv_heads"] = 4 if full.num_kv_heads == full.num_heads else 2
    if full.family == "moe":
        reduced["num_experts"] = 4
        reduced["experts_per_token"] = 2
    if full.ssm_version:
        reduced["ssm_state"] = 8
        reduced["ssm_head_dim"] = 16
        reduced["dt_rank"] = 8
    if full.shared_attn_every:
        reduced["shared_attn_every"] = 2
    return dataclasses.replace(full, **reduced)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=2, kind="decode")

# ---------------------------------------------------------------------------
# Paper-native workload presets (§5.1): the paper trains BERT/RoBERTa (DP) and
# LLaMA2-7B / ChatGLM-6B (MP, PP), and serves medium models.  We model each by
# an LM-family stand-in of matching scale; CV inference workloads (ResNet152,
# VGG19) enter the *simulator* as cost profiles (see core/simulator.py).
# ---------------------------------------------------------------------------

ROBERTA_LARGE = ModelConfig(
    name="roberta-large", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=50265,
    norm_type="layernorm",
)
BERT_BASE = ModelConfig(
    name="bert-base", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=30522,
    head_dim=64, norm_type="layernorm",
)
GPT2_LARGE = ModelConfig(
    name="gpt2-large", family="dense", num_layers=36, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=50257,
    head_dim=64, norm_type="layernorm", tie_embeddings=True,
)
LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=32000,
)
CHATGLM_6B = ModelConfig(
    name="chatglm-6b", family="dense", num_layers=28, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=65024,
)

PAPER_MODELS = {
    m.name: m
    for m in (ROBERTA_LARGE, BERT_BASE, GPT2_LARGE, LLAMA2_7B, CHATGLM_6B)
}

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "SMOKE_SHAPE",
    "SMOKE_DECODE",
    "PAPER_MODELS",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "SpecInFConfig",
    "get_config",
    "get_shape",
    "all_cells",
    "smoke_config",
    "shape_applicable",
    "mesh_axes",
]
