"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

54 Mamba2 layers (d_model=2560, ssm_state=64) with a single *shared*
attention(32H MHA)+MLP(d_ff=10240) block applied every 6 layers (weights
shared across applications, Zamba2 style).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_version=2,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
)
