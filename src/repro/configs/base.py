"""Config dataclasses for models, shapes, training, and SpecInF collocation.

Every assigned architecture gets its own module (``src/repro/configs/<id>.py``)
exporting ``CONFIG: ModelConfig``.  The registry in ``__init__`` resolves the
public ``--arch`` ids (dashed) to those modules.
"""
from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one decoder-style backbone.

    ``family`` selects the block layout:
      dense   -- attention + MLP every layer
      moe     -- attention + top-k MoE every layer
      ssm     -- Mamba block every layer (attention-free)
      hybrid  -- Mamba2 blocks with a *shared* attention+MLP block applied
                 every ``shared_attn_every`` layers (Zamba2 style)
      audio   -- dense backbone over precomputed EnCodec frame embeddings
      vlm     -- dense backbone over precomputed ViT patch embeddings + tokens
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba) ---
    ssm_state: int = 0
    ssm_version: int = 0  # 1 = Mamba1 (falcon-mamba), 2 = Mamba2 (zamba2)
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64  # Mamba2 only
    dt_rank: int = 0  # Mamba1 only; 0 -> ceil(d_model / 16)

    # --- attention options ---
    qkv_bias: bool = False  # qwen2 uses QKV bias
    qk_norm: bool = False  # qwen3 normalizes q/k per head
    rope_theta: float = 10_000.0
    # physical q-head padding for tensor parallelism (0 = disabled): pads
    # each GQA group to ``pad_heads_to // num_kv_heads`` physical slots and
    # masks the padded heads, so a 28H/kv4 model runs as 32 slots (8/group,
    # 7 real) and shards cleanly over a 16-way model axis.  Padded slots
    # contribute nothing and receive zero gradients — the logical
    # architecture is unchanged (see DESIGN.md §Perf / head padding).
    pad_heads_to: int = 0

    # --- norm options ---
    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    parametric_norm: bool = True  # olmo uses non-parametric LayerNorm

    # --- hybrid (Zamba2) ---
    shared_attn_every: int = 0  # apply the shared attn+MLP block every N layers

    # --- modality frontend ---
    embed_inputs: bool = False  # True: inputs are precomputed d_model embeddings

    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def num_heads_physical(self) -> int:
        """Physical q-head slots (>= num_heads when padded for TP)."""
        if self.pad_heads_to:
            assert self.pad_heads_to >= self.num_heads
            assert self.pad_heads_to % max(self.num_kv_heads, 1) == 0
            return self.pad_heads_to
        return self.num_heads

    @property
    def padded_heads(self) -> bool:
        return self.num_heads_physical != self.num_heads

    def padded_for_tp(self, tp: int) -> "ModelConfig":
        """Return a config whose physical q-head count divides ``tp`` (the
        §Perf head-padding optimization); self when already divisible or no
        padded layout exists."""
        if self.num_heads == 0 or self.num_heads % tp == 0:
            return self
        kv = max(self.num_kv_heads, 1)
        group = -(-self.num_heads // kv)  # logical heads per kv group
        group_phys = group
        while (kv * group_phys) % tp != 0:
            group_phys += 1
            if group_phys > 4 * group:  # no sane padding exists
                return self
        return dataclasses.replace(self, pad_heads_to=kv * group_phys)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def resolved_dt_rank(self) -> int:
        if self.dt_rank:
            return self.dt_rank
        return int(math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        """Mamba2 head count (d_inner / ssm_head_dim)."""
        if self.ssm_version != 2:
            return 0
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can run the 500k long-context decode shape."""
        return self.family in ("ssm", "hybrid")

    # --- analytic parameter counts (used by collocation + roofline) ------
    def param_count(self) -> int:
        """Total parameters (analytic; cross-checked by tests vs real trees)."""
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = 0
        # embeddings (+ untied LM head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.parametric_norm:
            n += d  # final norm
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            per_layer += self._attn_params(d, hd)
            if self.family == "moe":
                per_layer += self.num_experts * 3 * d * self.d_ff  # gate/up/down
                per_layer += d * self.num_experts  # router
            else:
                per_layer += 3 * d * self.d_ff
            per_layer += 2 * d if self.parametric_norm else 0  # two norms
            n += l * per_layer
        elif self.family == "ssm":
            n += l * (self._mamba1_params() + (d if self.parametric_norm else 0))
        elif self.family == "hybrid":
            n += l * (self._mamba2_params() + (d if self.parametric_norm else 0))
            if self.shared_attn_every:
                n += self._attn_params(d, hd) + 3 * d * self.d_ff + 2 * d
        return n

    def _attn_params(self, d: int, hd: int, physical: bool = True) -> int:
        h = self.num_heads_physical if physical else self.num_heads
        q = d * h * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = h * hd * d
        b = (h + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        qk = 2 * hd if self.qk_norm else 0
        return q + kv + o + b + qk

    def _mamba1_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        dtr = self.resolved_dt_rank
        n = d * 2 * di  # in_proj -> (x, z)
        n += di * self.ssm_conv + di  # depthwise conv + bias
        n += di * (dtr + 2 * ds)  # x_proj -> (dt, B, C)
        n += dtr * di + di  # dt_proj
        n += di * ds + di  # A_log, D
        n += di * d  # out_proj
        return n

    def _mamba2_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_num_heads
        n = d * (2 * di + 2 * ds + nh)  # in_proj -> (z, x, B, C, dt)
        n += (di + 2 * ds) * (self.ssm_conv + 1)  # conv over (x, B, C) + bias
        n += nh * 3  # A_log, D, dt_bias
        n += di  # gated RMSNorm weight
        n += di * d  # out_proj
        return n

    def active_param_count(self) -> int:
        """*Useful*-work parameters per token: excludes inactive experts
        (MoE) and masked padding heads (TP head padding)."""
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        if self.family != "moe":
            if not self.padded_heads:
                return self.param_count()
            pad = self._attn_params(d, hd, True) - self._attn_params(d, hd, False)
            if self.family == "hybrid" and self.shared_attn_every:
                return self.param_count() - pad
            return self.param_count() - l * pad
        per_layer = self._attn_params(d, hd, physical=False)
        per_layer += self.experts_per_token * 3 * d * self.d_ff
        per_layer += d * self.num_experts
        per_layer += 2 * d if self.parametric_norm else 0
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.parametric_norm:
            n += d
        return n + l * per_layer


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; reason string when skipped."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md §5)"
    return True, ""


# ---------------------------------------------------------------------------
# Training / runtime configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip_norm: float = 1.0
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "none"  # "none" | "dots" | "full"
    zero1: bool = False  # shard optimizer state over the data axis
    fsdp: bool = True  # additionally shard big params over the data axis
    layout: str = "tp"  # "tp" | "dp256" (model axis joins data parallelism)
    grad_compression: str = "none"  # "none" | "int8_ef" (pod-axis error feedback)
    microbatches: int = 1  # gradient accumulation (also PP-style chunking)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SpecInFConfig:
    """Algorithm-1 and monitor parameters (paper §3.3)."""

    alpha: int = 2  # conservative-phase threshold on the zero-count
    beta: int = 3  # incremental/stable boundary
    gamma: float = 2.0  # multiplicative token growth
    lower_limit: float = 8.0  # LL: token cap in the incremental phase
    upper_limit: float = 64.0  # UL: token cap in the stable phase
    token_seed: float = 1.0  # tokens restart from this after a zero
    window_ms: float = 2.0  # monitor sliding-window period (paper: 2ms)
    window_len: int = 64  # sliding-window capacity
    busy_hold_ms: float = 25.0  # per-instance busy hold after an online pull
    # (0 -> hold for the profiled max bubble, the paper's iteration-profiled
    # variant; 25ms default suits ~20ms services)
    hbm_limit_bytes: int = 16 * 1024**3  # v5e HBM (Principle-I budget)
    max_instances: int = 8

    # --- unified token-budget step (chunked prefill, DESIGN.md §7) ---
    #: Cap on the tokens one fused engine step may consume — decode tokens
    #: (1/slot), spec-verify chunks (gamma+1/slot), and prefill chunk
    #: tokens together.  0 = unmetered (steps sized by the bubble room
    #: alone).  With chunked prefill this bounds worst-case step latency:
    #: a long prompt streams across steps instead of monopolizing one.
    step_token_budget: float = 0.0
    #: Profiled per-prefill-token step cost in microstep-equivalents (one
    #: microstep == ``decode_microstep_s``).  ``SpecInFPolicy`` uses it to
    #: convert a bubble window into a prefill token budget, so a grant can
    #: never be overrun by a long prompt.  0 keeps prefill free in the
    #: cost model (the pre-§7 behavior).  The engine-side chunk width is
    #: the ``InferenceEngine(prefill_chunk=...)`` knob: None -> auto
    #: (DEFAULT_PREFILL_CHUNK for attention families), 0 -> monolithic
    #: bucket prefill.
    prefill_token_cost_steps: float = 0.0

    # --- revocable grants (failure model, DESIGN.md §9) ---
    #: Decode microsteps between revocation checks inside one quantum.  0
    #: keeps the pre-§9 single-dispatch step (a grant, once issued, always
    #: runs to completion).  >0 splits the fused decode/spec loop into
    #: sub-dispatches of at most this many microsteps and re-checks
    #: ``Grant.revocation`` between them, bounding how many tokens a
    #: quantum can run past the instant training resumes.
    revocation_check_steps: int = 0


# ---------------------------------------------------------------------------
# Speculative decoding (draft / target pairing)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecDecodeConfig:
    """Draft/target speculative-decoding pairing (``repro.spec``).

    The draft shares the target's family and vocabulary (verification is
    token-for-token) but runs a fraction of the depth/width; acceptance
    quality is a property of how well the draft tracks the target, while
    *correctness* is guaranteed by the verify pass alone."""

    draft_layers: int = 1  # draft depth (hybrid: rounded up to one cycle)
    draft_width_factor: float = 0.5  # d_model/d_ff shrink (1.0 = same width)
    gamma_buckets: tuple[int, ...] = (1, 2, 4)  # draft-length compile buckets
    mode: str = "greedy"  # "greedy" | "sample" | "simulated"
    sim_accept_p: float = 0.9  # Bernoulli acceptance for "simulated" mode
    draft_cost_ratio: float = 0.25  # draft step cost / target step cost
    accept_ewma: float = 0.5  # acceptance-rate smoothing (gamma controller)

    # --- pluggable proposers (spec.proposers, DESIGN.md §10) ---
    #: Candidate source: "auto" routes per quantum via the acceptance-EWMA
    #: router — on a draft-paired engine it registers BOTH the draft model
    #: and prompt-lookup n-gram and picks per quantum; on a plain engine it
    #: registers nothing (speculation stays opt-in: an engine without a
    #: draft pairing behaves exactly as before).  "draft"/"ngram" pin one
    #: proposer ("ngram" enables host-only speculation on a plain engine);
    #: "none" disables routing entirely (draft pairing alone decides).
    #: Host proposers are attention-family only — recurrent families always
    #: use the draft-model chain path.
    proposer: str = "auto"
    ngram_order: int = 3  # trailing n-gram matched by the lookup proposer
    tree_width: int = 1  # candidate branches per host-proposed round
    router_ewma: float = 0.5  # router acceptance smoothing
    router_init_acceptance: float = 0.7  # optimistic seed (try-everything)


def draft_config(target: ModelConfig, spec: SpecDecodeConfig = SpecDecodeConfig()) -> ModelConfig:
    """Derive a cheap draft model from ``target``: same family, vocabulary,
    and per-head dimension (token ids verify one-for-one; the engine keeps
    separate target and draft caches), with ``spec.draft_layers`` layers and
    width — d_model, d_ff, and the head *counts* — scaled by
    ``spec.draft_width_factor`` (GQA grouping and SSM divisibility
    preserved)."""
    layers = max(1, spec.draft_layers)
    changes: dict = {"name": target.name + "-draft"}
    if target.shared_attn_every:
        every = target.shared_attn_every
        changes["num_layers"] = max(every, -(-layers // every) * every)
    else:
        changes["num_layers"] = min(layers, target.num_layers)
    wf = spec.draft_width_factor
    if wf != 1.0:
        hd = target.resolved_head_dim
        if target.num_heads:
            heads = max(1, int(round(target.num_heads * wf)))
            kv = max(1, min(target.num_kv_heads, heads))
            while heads % kv:  # GQA grouping must stay exact
                kv -= 1
            changes["num_heads"] = heads
            changes["num_kv_heads"] = kv
            changes["head_dim"] = hd
            changes["d_model"] = max(hd, int(round(target.d_model * wf)))
        else:
            changes["d_model"] = max(16, int(round(target.d_model * wf)))
        if target.ssm_version == 2:  # Mamba2 heads must divide d_inner
            di = target.ssm_expand * changes["d_model"]
            changes["d_model"] = (
                -(-di // target.ssm_head_dim) * target.ssm_head_dim
            ) // target.ssm_expand
        if target.d_ff:
            changes["d_ff"] = max(16, int(round(target.d_ff * wf)))
        if target.dt_rank:
            changes["dt_rank"] = max(1, int(round(target.dt_rank * wf)))
    return dataclasses.replace(target, **changes)


def mesh_axes(multi_pod: bool = False) -> tuple[tuple[int, ...], tuple[str, ...]]:
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")
