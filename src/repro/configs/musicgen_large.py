"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48 layers, d_model=2048, 32H MHA (kv=32), d_ff=8192, vocab=2048 (EnCodec
codebook).  The EnCodec frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings of width d_model (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    norm_type="layernorm",
    embed_inputs=True,
)
